"""LIF neurons with surrogate gradients, and the Temporal-Fused LIF (TFLIF).

Dynamics (spikingjelly-style LIF used by Spikformer, v_reset = 0):

    h_t = v_{t-1} + (x_t - v_{t-1}) / tau        (charge)
    s_t = H(h_t - v_th)                          (fire; H = Heaviside)
    v_t = h_t * (1 - s_t)                        (hard reset)

Backward uses the atan surrogate  dH/du ~= alpha / (2 * (1 + (pi/2*alpha*u)^2)).

TFLIF is VESTA's contribution: all T timesteps are processed in one fused pass
(T lives in registers, outputs are emitted as packed spikes), and the BN layer
that always precedes LIF is folded into the preceding conv/linear (scale into
weights, bias into the accumulator) so BN never runs as a separate layer. The
threshold comparison happens inside the same fused op ("subtract v_th from the
BN bias" in the paper's per-timestep comparator).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

TAU = 2.0
V_TH = 1.0
SURROGATE_ALPHA = 2.0


@jax.custom_vjp
def spike_fn(u):
    """Heaviside with atan surrogate gradient. u = membrane - threshold."""
    return (u >= 0.0).astype(u.dtype)


def _spike_fwd(u):
    return spike_fn(u), u


def _spike_bwd(u, g):
    sg = SURROGATE_ALPHA / (2.0 * (1.0 + (jnp.pi / 2.0 * SURROGATE_ALPHA * u) ** 2))
    return (g * sg,)


spike_fn.defvjp(_spike_fwd, _spike_bwd)


def lif_step(v, x, *, tau: float = TAU, v_th=V_TH):
    """One LIF timestep. Returns (v_next, spike). ``v_th`` may be a scalar
    or a per-channel array broadcastable against x (the int8-weight route
    folds its dequantization scale into the threshold as v_th/s)."""
    h = v + (x - v) / tau
    s = spike_fn(h - v_th)
    v_next = h * (1.0 - s)
    return v_next, s


def tflif(x, *, tau: float = TAU, v_th=V_TH, time_axis: int = 0):
    """Temporal-Fused LIF: input (T, ...) accumulator values -> (T, ...) spikes.

    The whole T axis is processed in one fused scan (T stays on-chip); pair with
    ``core.spike.pack_bits`` to store the result 1-bit-per-spike, and with
    ``fold_bn`` below so no separate BN layer ever executes. The Pallas TPU
    kernel version lives in ``repro.kernels.tflif``; this is the reference
    (identical math, used for training via surrogate-grad BPTT).
    """
    x = jnp.moveaxis(x, time_axis, 0)
    v0 = jnp.zeros_like(x[0])

    def step(v, xt):
        v_next, s = lif_step(v, xt, tau=tau, v_th=v_th)
        return v_next, s

    _, spikes = jax.lax.scan(step, v0, x)
    return jnp.moveaxis(spikes, 0, time_axis)


# ---------------------------------------------------------------------------
# BN folding (the TFLIF "bias - threshold" merge)
# ---------------------------------------------------------------------------

def bn_init(c: int, dtype=jnp.float32):
    return {
        "scale": jnp.ones((c,), dtype),
        "bias": jnp.zeros((c,), dtype),
        "mean": jnp.zeros((c,), dtype),
        "var": jnp.ones((c,), dtype),
    }


def bn_apply(p, x, *, eps: float = 1e-5):
    """Inference-mode BN over the last axis (reference path, pre-fold)."""
    inv = jax.lax.rsqrt(p["var"].astype(jnp.float32) + eps)
    g = p["scale"].astype(jnp.float32) * inv
    b = p["bias"].astype(jnp.float32) - p["mean"].astype(jnp.float32) * g
    return x.astype(jnp.float32) * g + b


def fold_bn(kernel, bias, bn, *, eps: float = 1e-5):
    """Fold inference BN into the preceding linear/conv: returns (kernel', bias')
    such that BN(x @ k + b) == x @ k' + b'. kernel: (..., d_in, C)."""
    inv = jax.lax.rsqrt(bn["var"].astype(jnp.float32) + eps)
    g = bn["scale"].astype(jnp.float32) * inv                      # (C,)
    b = bn["bias"].astype(jnp.float32) - bn["mean"].astype(jnp.float32) * g
    kernel_f = kernel.astype(jnp.float32) * g                      # scale out-channels
    bias_f = (bias.astype(jnp.float32) * g + b) if bias is not None else b
    return kernel_f.astype(kernel.dtype), bias_f


def batch_stats(x, axes):
    """Training-mode batch statistics for BN (used by the training path)."""
    mean = jnp.mean(x, axis=axes)
    var = jnp.var(x, axis=axes)
    return mean, var


def bn_train_apply(p, x, axes, *, eps: float = 1e-5, momentum: float = 0.9):
    """Training BN: normalize with batch stats; returns (y, new_stats)."""
    x32 = x.astype(jnp.float32)
    mean, var = batch_stats(x32, axes)
    inv = jax.lax.rsqrt(var + eps)
    y = (x32 - mean) * inv * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    new = {
        "mean": momentum * p["mean"] + (1 - momentum) * mean,
        "var": momentum * p["var"] + (1 - momentum) * var,
    }
    return y.astype(x.dtype), new
