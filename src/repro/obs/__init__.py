"""``repro.obs`` — tracing + metrics for the serving stack.

One tracer surface shared by every ``ServeClient`` (sync engine, async
runtime, fleet) and the event-stream session; bounded metrics (log-bucket
latency histograms, gauges, counters) backing the shared ``stats()``
schema; Chrome-trace/Perfetto and JSONL export. See ``obs/README.md`` for
the span taxonomy and the ring-buffer contract.
"""
from .export import (SPANS_SCHEMA_VERSION, load_spans_jsonl, to_chrome_trace,
                     write_chrome_trace, write_spans_jsonl)
from .metrics import Counter, Gauge, LatencyHistogram, MetricsRegistry
from .trace import (LIFECYCLE, NULL_TRACER, NullTracer, Span, Tracer)

__all__ = [
    "LIFECYCLE",
    "NULL_TRACER",
    "NullTracer",
    "Span",
    "Tracer",
    "Counter",
    "Gauge",
    "LatencyHistogram",
    "MetricsRegistry",
    "SPANS_SCHEMA_VERSION",
    "load_spans_jsonl",
    "to_chrome_trace",
    "write_chrome_trace",
    "write_spans_jsonl",
]
