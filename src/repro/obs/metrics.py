"""Bounded serving metrics: counters, gauges, and log-bucketed latency
histograms.

The original percentile path (``infer.engine.latency_summary``) holds
every completed request's latency and sorts at report time — O(requests)
memory, which a long-lived server cannot afford at "millions of users"
scale. ``LatencyHistogram`` replaces it with **log-spaced buckets**: each
observation lands in the bucket whose edges bracket it, so a
million-request run holds O(buckets) floats and a percentile query walks
the cumulative counts.

The accuracy contract, documented and tested: bucket edges grow by
``growth`` (default 1.05), so a percentile's representative value is
within **one bucket width — at most ``growth - 1`` (5%) relative
error** — of the exact order statistic, and always clamped into the
observed ``[min, max]`` (a single sample, or an all-equal population,
reports exactly). The mean is exact (sum/count), and zero/sub-range
observations land in a dedicated underflow bucket represented by the
observed minimum.

``MetricsRegistry`` is the flat namespace the serving stack publishes
into (scheduler EWMAs as gauges, queue-depth watermarks, drop counters);
``snapshot()`` renders it as one plain dict for stats/debug endpoints.
"""
from __future__ import annotations

import math
import threading


class Counter:
    """A monotonically increasing count (requests, drops, spans)."""

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """A last-value-wins reading that also tracks its high-watermark —
    the ``max`` is what ``queue_depth_peak`` reports, so a burst that
    grazed the bound survives every later quiet sample."""

    def __init__(self, name: str):
        self.name = name
        self.value: float | None = None
        self.max: float | None = None

    def set(self, value: float) -> None:
        self.value = value
        if self.max is None or value > self.max:
            self.max = value


class LatencyHistogram:
    """Log-bucketed latency distribution with bounded percentile error.

        h = LatencyHistogram()           # 1us..100s span, 5% buckets
        h.observe(0.012)
        h.percentile(99)                 # within growth-1 of exact
        h.summary()                      # the latency_* stats fields

    Memory is fixed at construction: ``len(counts)`` buckets regardless
    of how many observations arrive. Thread-safe (one lock per observe —
    the serving workers complete requests concurrently).
    """

    def __init__(self, *, lo: float = 1e-6, hi: float = 100.0,
                 growth: float = 1.05):
        if not 0 < lo < hi:
            raise ValueError(f"need 0 < lo < hi, got lo={lo!r} hi={hi!r}")
        if growth <= 1.0:
            raise ValueError(f"growth must be > 1, got {growth!r}")
        self.lo, self.hi, self.growth = float(lo), float(hi), float(growth)
        self._log_lo = math.log(lo)
        self._log_growth = math.log(growth)
        n = int(math.ceil((math.log(hi) - self._log_lo) / self._log_growth))
        # +2: an underflow bucket (index 0, readings < lo — including the
        # exact 0.0 an empty request reports) and an overflow bucket
        self.counts = [0] * (n + 2)
        self.count = 0
        self.sum = 0.0
        self.min: float | None = None
        self.max: float | None = None
        self._lock = threading.Lock()

    @property
    def error_bound(self) -> float:
        """Documented worst-case relative percentile error: one bucket
        width."""
        return self.growth - 1.0

    def _index(self, seconds: float) -> int:
        if seconds < self.lo:
            return 0
        if seconds >= self.hi:
            return len(self.counts) - 1
        return 1 + int((math.log(seconds) - self._log_lo)
                       / self._log_growth)

    def observe(self, seconds: float) -> None:
        seconds = float(seconds)
        if seconds < 0:
            raise ValueError(f"latency must be >= 0, got {seconds!r}")
        i = min(self._index(seconds), len(self.counts) - 1)
        with self._lock:
            self.counts[i] += 1
            self.count += 1
            self.sum += seconds
            if self.min is None or seconds < self.min:
                self.min = seconds
            if self.max is None or seconds > self.max:
                self.max = seconds

    def _representative(self, i: int) -> float:
        """A bucket's stand-in value: the geometric midpoint of its edges
        (underflow/overflow use their finite edge), clamped to the
        observed range — which makes single-sample and all-equal
        populations exact."""
        if i == 0:
            v = self.lo
        elif i == len(self.counts) - 1:
            v = self.hi
        else:
            e0 = self.lo * self.growth ** (i - 1)
            v = e0 * math.sqrt(self.growth)
        return max(self.min, min(self.max, v))

    def percentile(self, q: float) -> float | None:
        """The q-th percentile (0..100), ``None`` when empty. Nearest-rank
        over the cumulative bucket counts; the returned value is the
        holding bucket's representative, so the error is bounded by one
        bucket width (``error_bound``) relative."""
        with self._lock:
            if not self.count:
                return None
            rank = max(1, math.ceil(q / 100.0 * self.count))
            seen = 0
            for i, c in enumerate(self.counts):
                seen += c
                if seen >= rank:
                    return self._representative(i)
            return self._representative(len(self.counts) - 1)

    @property
    def mean(self) -> float | None:
        return self.sum / self.count if self.count else None

    def summary(self, *, prefix: str = "latency_") -> dict:
        """The shared stats vocabulary (``latency_p50_s``/``p95``/``p99``/
        ``mean_s``), all ``None`` when no request ever completed — the
        empty window reports absence, it does not crash the caller."""
        if not self.count:
            return {f"{prefix}{k}": None for k in ("p50_s", "p95_s",
                                                   "p99_s", "mean_s")}
        return {
            f"{prefix}p50_s": round(self.percentile(50), 6),
            f"{prefix}p95_s": round(self.percentile(95), 6),
            f"{prefix}p99_s": round(self.percentile(99), 6),
            f"{prefix}mean_s": round(self.mean, 6),
        }


class MetricsRegistry:
    """A flat, typed metric namespace: ``counter``/``gauge``/``histogram``
    get-or-create by name, and asking for an existing name as a different
    type fails loudly (two subsystems silently sharing "queue_depth" as
    different shapes is a reporting bug, not a convenience)."""

    def __init__(self):
        self._metrics: dict[str, object] = {}
        self._lock = threading.Lock()

    def _get(self, name: str, cls, factory):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = factory()
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} is a {type(m).__name__}, not a "
                    f"{cls.__name__}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter, lambda: Counter(name))

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge, lambda: Gauge(name))

    def histogram(self, name: str, **kw) -> LatencyHistogram:
        return self._get(name, LatencyHistogram,
                         lambda: LatencyHistogram(**kw))

    def names(self) -> list[str]:
        with self._lock:
            return sorted(self._metrics)

    def snapshot(self) -> dict:
        """Every metric as plain data: counters to ints, gauges to
        ``{value, max}``, histograms to their summary dict."""
        with self._lock:
            items = list(self._metrics.items())
        out = {}
        for name, m in items:
            if isinstance(m, Counter):
                out[name] = m.value
            elif isinstance(m, Gauge):
                out[name] = {"value": m.value, "max": m.max}
            else:
                out[name] = {"count": m.count, **m.summary()}
        return out
