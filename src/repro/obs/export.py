"""Trace export: Chrome trace-event / Perfetto JSON and a versioned,
re-loadable JSONL span format.

Two formats, two audiences:

* ``write_chrome_trace`` — the `Trace Event Format`_ JSON that
  https://ui.perfetto.dev (and chrome://tracing) loads directly. Layout:
  **one pid per replica** (pid 0 is the single-worker/sync path), a
  ``worker`` tid for batch-scoped spans (assemble/step), a ``scheduler``
  tid for placement, per-request tids for the rid-scoped lifecycle spans
  (concurrent requests must not nest on one thread lane), and **counter
  tracks** ("C" events) for queue depth and occupancy samples.

* ``write_spans_jsonl`` / ``load_spans_jsonl`` — the analysis format
  ``scripts/trace_report.py`` consumes: a header line carrying
  ``spans_version`` and the tracer's ``dropped_spans`` (loss travels WITH
  the data), then one JSON object per span. ``load_spans_jsonl`` inverts
  it back to ``Span`` records, so a trace file is a first-class input,
  not a write-only artifact.

.. _Trace Event Format:
   https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU
"""
from __future__ import annotations

import json

from .trace import Span

SPANS_SCHEMA_VERSION = 1
SPANS_KIND = "repro.obs.spans"

# fixed tid lanes inside each replica's pid; request lanes start above them
_TID_WORKER = 0
_TID_SCHEDULER = 1
_TID_SESSION = 2
_TID_REQUEST_BASE = 10

_LANE_NAMES = {_TID_WORKER: "worker", _TID_SCHEDULER: "scheduler",
               _TID_SESSION: "session"}


def _tid_for(span: Span) -> int:
    if span.rid is not None:
        return _TID_REQUEST_BASE + int(span.rid)
    if span.name == "place":
        return _TID_SCHEDULER
    if span.category == "window":
        return _TID_SESSION
    return _TID_WORKER


def to_chrome_trace(spans, *, dropped_spans: int = 0) -> dict:
    """Render spans as a Chrome trace-event dict (Perfetto-loadable).

    Timestamps are rebased to the earliest span (the injected serving
    clock has an arbitrary origin) and scaled to microseconds, the
    format's unit."""
    spans = list(spans)
    t_base = min((s.t0 for s in spans), default=0.0)
    events = []
    seen_pids: dict[int, set] = {}
    for s in spans:
        pid = 0 if s.replica is None else int(s.replica)
        ts = (s.t0 - t_base) * 1e6
        if s.category == "counter":
            seen_pids.setdefault(pid, set())
            events.append({"ph": "C", "name": s.name, "pid": pid, "ts": ts,
                           "args": {s.name: s.value}})
            continue
        tid = _tid_for(s)
        seen_pids.setdefault(pid, set()).add(tid)
        args = {k: v for k, v in (("rid", s.rid), ("bucket", s.bucket),
                                  ("occupancy", s.occupancy),
                                  ("value", s.value)) if v is not None}
        events.append({"ph": "X", "cat": s.category, "name": s.name,
                       "pid": pid, "tid": tid, "ts": ts,
                       "dur": max(0.0, (s.t1 - s.t0) * 1e6), "args": args})
    # metadata: name each replica's process and each fixed lane
    for pid, tids in sorted(seen_pids.items()):
        events.append({"ph": "M", "name": "process_name", "pid": pid,
                       "args": {"name": f"replica {pid}"}})
        for tid in sorted(tids):
            name = _LANE_NAMES.get(tid, f"request {tid - _TID_REQUEST_BASE}")
            events.append({"ph": "M", "name": "thread_name", "pid": pid,
                           "tid": tid, "args": {"name": name}})
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {"spans_version": SPANS_SCHEMA_VERSION,
                      "dropped_spans": int(dropped_spans)},
    }


def write_chrome_trace(path, tracer, *, dropped_spans=None) -> int:
    """Write a tracer's spans as Perfetto-loadable JSON; returns the span
    count. Accepts a tracer or a plain span iterable (pass
    ``dropped_spans`` explicitly for the latter)."""
    spans = tracer.spans() if hasattr(tracer, "spans") else list(tracer)
    if dropped_spans is None:
        dropped_spans = getattr(tracer, "dropped_spans", 0)
    doc = to_chrome_trace(spans, dropped_spans=dropped_spans)
    with open(path, "w") as f:
        json.dump(doc, f)
    return len(spans)


def write_spans_jsonl(path, tracer, *, meta: dict | None = None,
                      dropped_spans=None) -> int:
    """Write the versioned JSONL span file: one header line (schema
    version, span count, ``dropped_spans``, caller ``meta``), then one
    object per span. Returns the span count."""
    spans = tracer.spans() if hasattr(tracer, "spans") else list(tracer)
    if dropped_spans is None:
        dropped_spans = getattr(tracer, "dropped_spans", 0)
    header = {"kind": SPANS_KIND, "spans_version": SPANS_SCHEMA_VERSION,
              "spans": len(spans), "dropped_spans": int(dropped_spans)}
    if meta:
        header["meta"] = dict(meta)
    with open(path, "w") as f:
        f.write(json.dumps(header) + "\n")
        for s in spans:
            f.write(json.dumps({
                "cat": s.category, "name": s.name,
                "t0": s.t0, "t1": s.t1, "rid": s.rid,
                "replica": s.replica, "bucket": s.bucket,
                "occ": s.occupancy, "value": s.value}) + "\n")
    return len(spans)


def load_spans_jsonl(path) -> tuple[dict, list[Span]]:
    """Load a span JSONL file back: ``(header, spans)``. Refuses files
    that are not this format or a newer schema than this code reads —
    a silent partial parse would corrupt every downstream report."""
    with open(path) as f:
        first = f.readline()
        if not first.strip():
            raise ValueError(f"{path}: empty file, not a span trace")
        header = json.loads(first)
        if header.get("kind") != SPANS_KIND:
            raise ValueError(
                f"{path}: kind={header.get('kind')!r}, expected "
                f"{SPANS_KIND!r} — not a span trace file")
        version = header.get("spans_version")
        if version != SPANS_SCHEMA_VERSION:
            raise ValueError(
                f"{path}: spans_version={version!r}; this reader speaks "
                f"{SPANS_SCHEMA_VERSION}")
        spans = []
        for line in f:
            if not line.strip():
                continue
            d = json.loads(line)
            spans.append(Span(d["cat"], d["name"], d["t0"], d["t1"],
                              d.get("rid"), d.get("replica"),
                              d.get("bucket"), d.get("occ"),
                              d.get("value")))
    if len(spans) != header.get("spans", len(spans)):
        raise ValueError(
            f"{path}: header promises {header.get('spans')} spans, file "
            f"holds {len(spans)} — truncated trace")
    return header, spans
