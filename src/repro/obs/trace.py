"""Request-lifecycle tracing: a preallocated ring buffer of span records.

The serving stack (``MicroBatchEngine``, ``AsyncServeRuntime``,
``ServeFleet``, ``EventStreamSession``) emits every request's canonical
lifecycle as spans::

    admit -> queue -> place -> assemble -> step -> complete

plus ``window`` spans from the event-stream session, ``layer`` spans from
``CompiledModel.profile_step``, and ``counter`` samples (queue depth,
occupancy). A span is nine scalar fields — category, name, start, end,
request id, replica, bucket, occupancy, value — and the whole record set
lives in a **preallocated column-oriented ring**: appending writes nine
existing slots under a lock and allocates nothing, so tracing sits on the
serving hot path without feeding the allocator. When the ring wraps, the
OLDEST span is overwritten and ``dropped_spans`` counts the loss loudly —
a trace that silently forgot its beginning would lie about request
chains, so every consumer (``obs.export``, ``scripts/trace_report.py``)
carries the counter alongside the spans.

The untraced path costs one attribute check: every emit site is

    if tracer.enabled:
        tracer.span(...)

and the default ``NULL_TRACER`` answers ``enabled = False``.

Timestamps come from the tracer's **injected clock** (the same policy as
the pure scheduler): a test drives a fake clock and pins the exact span
table, just like the PR 9 decision tables. Emit sites that already
measured ``t0``/``t1`` on the serving clock pass them explicitly; a bare
``span()`` stamps an instant on the tracer's own clock.
"""
from __future__ import annotations

import threading
import time
import typing

SPAN_FIELDS = ("category", "name", "t0", "t1", "rid", "replica", "bucket",
               "occupancy", "value")

# The canonical request lifecycle, in order. ``place``/``assemble``/``step``
# are batch-scoped (rid None — one span covers every request in the fused
# batch); the rid-scoped chain every admitted request completes is
# admit -> queue -> complete.
LIFECYCLE = ("admit", "queue", "place", "assemble", "step", "complete")


class Span(typing.NamedTuple):
    """One structured trace record. ``t0 == t1`` marks an instant event
    (counters, shed markers); ``value`` is the counter sample or a
    span-specific scalar (rows for ``step``, depth for ``queue_depth``)."""
    category: str
    name: str
    t0: float
    t1: float
    rid: int | None = None
    replica: int | None = None
    bucket: int | None = None
    occupancy: float | None = None
    value: float | None = None

    @property
    def duration_s(self) -> float:
        return self.t1 - self.t0


class NullTracer:
    """The disabled tracer: ``enabled`` is False and every method is a
    no-op, so instrumented code pays exactly one attribute check when
    tracing is off. Shared as the module-level ``NULL_TRACER`` default —
    allocating one per client would be the allocation tracing exists to
    avoid."""

    enabled = False
    dropped_spans = 0
    capacity = 0

    def span(self, category, name, **kw) -> None:
        pass

    def counter(self, name, value, **kw) -> None:
        pass

    def spans(self) -> list:
        return []

    def clear(self) -> None:
        pass

    def __len__(self) -> int:
        return 0


NULL_TRACER = NullTracer()


class Tracer:
    """A bounded, thread-safe span recorder.

        tr = Tracer(capacity=65536)
        tr.span("request", "admit", t0=a, t1=b, rid=7)
        tr.counter("queue_depth", 12)
        tr.spans()          # chronological list[Span]
        tr.dropped_spans    # how many oldest spans the ring overwrote

    The ring is column-oriented: nine preallocated Python lists of
    ``capacity`` slots each. ``span()`` writes one slot per column at the
    write head and advances it — O(1), zero allocation, one lock. Span
    objects only materialize in ``spans()``, off the hot path.
    """

    enabled = True

    def __init__(self, capacity: int = 65536, *, clock=time.perf_counter):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity!r}")
        self.capacity = int(capacity)
        self.clock = clock
        self.dropped_spans = 0
        self._lock = threading.Lock()
        self._head = 0          # next write slot
        self._count = 0         # live spans (<= capacity)
        n = self.capacity
        self._cat = [None] * n
        self._name = [None] * n
        self._t0 = [0.0] * n
        self._t1 = [0.0] * n
        self._rid = [None] * n
        self._replica = [None] * n
        self._bucket = [None] * n
        self._occ = [None] * n
        self._value = [None] * n

    def span(self, category: str, name: str, *, t0: float | None = None,
             t1: float | None = None, rid: int | None = None,
             replica: int | None = None, bucket: int | None = None,
             occupancy: float | None = None,
             value: float | None = None) -> None:
        """Record one span. ``t0`` defaults to now (tracer clock); ``t1``
        defaults to ``t0`` (an instant event)."""
        if t0 is None:
            t0 = self.clock()
        if t1 is None:
            t1 = t0
        with self._lock:
            i = self._head
            self._cat[i] = category
            self._name[i] = name
            self._t0[i] = t0
            self._t1[i] = t1
            self._rid[i] = rid
            self._replica[i] = replica
            self._bucket[i] = bucket
            self._occ[i] = occupancy
            self._value[i] = value
            self._head = (i + 1) % self.capacity
            if self._count == self.capacity:
                self.dropped_spans += 1     # overwrote the oldest span
            else:
                self._count += 1

    def counter(self, name: str, value, *, t: float | None = None,
                replica: int | None = None) -> None:
        """Record one counter sample (queue depth, occupancy) — an instant
        span of category "counter" whose ``value`` is the reading; export
        renders these as Perfetto counter tracks."""
        self.span("counter", name, t0=t, replica=replica,
                  value=float(value))

    def spans(self) -> list[Span]:
        """Every live span, oldest first (chronological append order —
        the ring start, not index 0, after a wrap)."""
        with self._lock:
            n, cap = self._count, self.capacity
            start = (self._head - n) % cap
            out = []
            for k in range(n):
                i = (start + k) % cap
                out.append(Span(self._cat[i], self._name[i], self._t0[i],
                                self._t1[i], self._rid[i], self._replica[i],
                                self._bucket[i], self._occ[i],
                                self._value[i]))
        return out

    def clear(self) -> None:
        """Empty the ring (capacity and ``dropped_spans`` survive — the
        drop counter is an account of loss, not of current contents)."""
        with self._lock:
            self._head = 0
            self._count = 0

    def __len__(self) -> int:
        with self._lock:
            return self._count
